"""Model-zoo kernels: faithful hetIR reductions of the repo's real
workloads (``src/repro/kernels/*``), each paired with a *bit-exact*
NumPy oracle.

These are not microbenchmarks: they are the flash-decode attention row,
the top-1 MoE router + grouped matvec, the RG-LRU gated linear
recurrence and the mLSTM matrix-memory cell, rebuilt on the hetIR
Builder so one architecture-agnostic Program runs unmodified on the
interp, vectorized and pallas substrates.  Unlike the reference models
in ``kernels/*/ref.py`` (which compare under a tolerance), every oracle
here reproduces the kernel's exact float32 operation *order* — one op,
one rounding, lane-order sequential folds for the collectives, and
``portable_math.exp_np`` for every EXP — so conformance is asserted
with ``assert_array_equal``, the same contract the suite enjoys.

Oracle contract (documented in docs/ZOO.md):

* every scalar op is a single float32 rounding in program order;
* ``REDUCE_ADD``/``SCAN_ADD`` fold strictly in lane order from a
  zero of the destination dtype;
* ``REDUCE_MAX`` is an exact maximum (order-independent);
* ``EXP`` is the portable software exp shared by every backend
  (Cody-Waite reduction + Cephes polynomial, flush-to-zero outputs).

Registration happens at import under the ``"zoo"`` namespace via
:func:`repro.core.kernels_suite.register_kernel`, so registry-aware
tooling (``example_launch``, roofline, the serving demo) picks the zoo
up with the same one-liners it uses for the suite.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from ..core import hetir as ir
from ..core.hetir import Builder, Ptr, Scalar
from ..core.kernels_suite import register_kernel
from ..core.backends.portable_math import exp_np

_F32 = np.float32


def _f32(x) -> np.float32:
    return np.float32(x)


# ---------------------------------------------------------------------------
# attn_decode — single-query flash-decode attention row
# ---------------------------------------------------------------------------

ATTN_D = 16   #: head dimension (threads 0..D-1 own one output feature)
ATTN_T = 32   #: kv tile size == block size (one tile of keys per segment)


def attn_decode(D: int = ATTN_D, T: int = ATTN_T) -> Tuple[ir.Program, Callable]:
    """One decode step of flash attention for a single query token.

    Grid = heads, block = one kv tile of ``T`` lanes.  Each tile
    iteration computes the QK^T scores for ``T`` keys, folds them into
    the online (max, sum) softmax state via ``REDUCE_MAX``/``EXP``/
    ``REDUCE_ADD``, stages the probabilities through shared memory, and
    accumulates PV — with two barriers per tile, so a decode step is
    many short segments the scheduler can preempt (and the fleet can
    checkpoint/migrate) between.
    """
    b = Builder("attn_decode",
                [Ptr("Q"), Ptr("K"), Ptr("V"), Ptr("O"),
                 Scalar("ntiles"), Scalar("scale", ir.F32)],
                shared_size=D + T)
    h = b.block_id()
    tid = b.thread_id()
    dd = b.const(D)
    tt = b.const(T)
    ntl = b.param("ntiles")
    scale = b.param("scale")
    # feature index clamped for lanes >= D (they help stage p but own no
    # output feature; clamping keeps their V loads in bounds)
    jcl = b.select(tid < dd, tid, b.const(0))
    with b.when(tid < dd):
        b.store_shared(tid, b.load("Q", h * dd + tid))
    b.barrier("q-staged")
    m = b.var(b.const(float("-inf"), ir.F32), hint="m")
    l = b.var(b.const(0.0, ir.F32), hint="l")
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop("ntiles", hint="kt") as kt:
        row = (h * ntl + kt) * tt + tid        # this lane's key row
        s = b.var(b.const(0.0, ir.F32), hint="s")
        with b.loop(D, hint="d") as d:
            b.assign(s, s + b.load_shared(d) * b.load("K", row * dd + d))
        sv = s * scale
        mn = b.maximum(m, b.reduce_max(sv))
        p = b.exp(sv - mn)
        b.store_shared(dd + tid, p)
        corr = b.exp(m - mn)
        b.assign(m, mn)
        b.assign(l, l * corr + b.reduce_add(p))
        b.barrier("p-staged")
        pv = b.var(b.const(0.0, ir.F32), hint="pv")
        with b.loop(T, hint="i") as i:
            vrow = (h * ntl + kt) * tt + i
            b.assign(pv, pv + b.load_shared(dd + i)
                     * b.load("V", vrow * dd + jcl))
        with b.when(tid < dd):
            b.assign(acc, acc * corr + pv)
        b.barrier("p-consumed")
    with b.when(tid < dd):
        b.store("O", h * dd + tid, acc / l)
    prog = b.done()

    def oracle(args):
        ntiles = int(args["ntiles"])
        scale = _f32(args["scale"])
        Q = np.asarray(args["Q"], _F32)
        K = np.asarray(args["K"], _F32)
        V = np.asarray(args["V"], _F32)
        H = Q.size // D
        S = ntiles * T
        Kr = K.reshape(H, S, D)
        Vr = V.reshape(H, S, D)
        out = np.array(args["O"], _F32)
        for h in range(H):
            q = Q[h * D:(h + 1) * D]
            m = _f32(-np.inf)
            l = _f32(0.0)
            acc = np.zeros(D, _F32)
            for kt in range(ntiles):
                rows = slice(kt * T, (kt + 1) * T)
                # per-lane sequential dot, vectorised across lanes
                s = np.zeros(T, _F32)
                for d in range(D):
                    s = s + q[d] * Kr[h, rows, d]
                sv = s * scale
                mn = np.maximum(m, np.max(sv))
                p = exp_np(sv - mn)
                corr = exp_np(_f32(m - mn))
                m = mn
                red = np.zeros((), _F32)
                for i in range(T):                 # lane-order fold
                    red = np.add(red, p[i], dtype=_F32)
                l = _f32(_f32(l * corr) + red)
                pv = np.zeros(D, _F32)
                for i in range(T):                 # sequential PV fold
                    pv = pv + p[i] * Vr[h, kt * T + i, :]
                acc = acc * corr + pv
            out[h * D:(h + 1) * D] = acc / l
        return {"O": out}

    return prog, oracle


# ---------------------------------------------------------------------------
# moe_route_gmm — top-1 router + grouped (gathered) expert matvec
# ---------------------------------------------------------------------------

MOE_E = 4   #: experts
MOE_F = 8   #: model width (router in-dim == expert in/out-dim)


def moe_route_gmm() -> Tuple[ir.Program, Callable]:
    """Top-1 MoE routing and the routed expert matvec, one token per
    thread.  The router is an argmax over per-expert logits (strict
    ``>``, first winner kept — the reference ``moe_gmm_ref`` tie rule);
    the expert weights are then *gathered* through the data-dependent
    expert index, the access pattern block_lower must legitimately
    refuse (``opaque-index``/``unprovable-base``).  The winning logit
    gates the output through a sigmoid built on the portable EXP.
    """
    b = Builder("moe_route_gmm",
                [Ptr("X"), Ptr("Wg"), Ptr("We"), Ptr("Y"),
                 Ptr("Eidx", ir.I32), Scalar("E"), Scalar("F")])
    n = b.global_id(0)
    Fp = b.param("F")
    best = b.var(b.const(float("-inf"), ir.F32), hint="best")
    bidx = b.var(b.const(0), hint="bidx")
    with b.loop("E", hint="e") as e:
        dot = b.var(b.const(0.0, ir.F32), hint="dot")
        with b.loop("F", hint="k") as k:
            b.assign(dot, dot + b.load("X", n * Fp + k)
                     * b.load("Wg", e * Fp + k))
        better = dot > best
        b.assign(best, b.select(better, dot, best))
        b.assign(bidx, b.select(better, e, bidx))
    b.store("Eidx", n, bidx)
    gate = b.const(1.0, ir.F32) / (b.const(1.0, ir.F32)
                                   + b.exp(b.const(0.0, ir.F32) - best))
    with b.loop("F", hint="f") as f:
        acc = b.var(b.const(0.0, ir.F32), hint="acc")
        with b.loop("F", hint="k2") as k2:
            b.assign(acc, acc + b.load("We", (bidx * Fp + f) * Fp + k2)
                     * b.load("X", n * Fp + k2))
        b.store("Y", n * Fp + f, acc * gate)
    prog = b.done()

    def oracle(args):
        E = int(args["E"])
        F = int(args["F"])
        X = np.asarray(args["X"], _F32)
        Wg = np.asarray(args["Wg"], _F32).reshape(E, F)
        We = np.asarray(args["We"], _F32).reshape(E, F, F)
        N = X.size // F
        Xm = X.reshape(N, F)
        Y = np.array(args["Y"], _F32).reshape(N, F)
        Eidx = np.array(args["Eidx"], np.int32)
        for nn in range(N):
            best = _f32(-np.inf)
            bi = 0
            for e in range(E):
                dot = _f32(0.0)
                for k in range(F):
                    dot = _f32(dot + _f32(Xm[nn, k] * Wg[e, k]))
                if dot > best:
                    best, bi = dot, e
            Eidx[nn] = bi
            gate = _f32(_f32(1.0)
                        / _f32(_f32(1.0) + exp_np(_f32(_f32(0.0) - best))))
            for ff in range(F):
                acc = _f32(0.0)
                for k in range(F):
                    acc = _f32(acc + _f32(We[bi, ff, k] * Xm[nn, k]))
                Y[nn, ff] = _f32(acc * gate)
        return {"Y": Y.reshape(-1), "Eidx": Eidx}

    return prog, oracle


# ---------------------------------------------------------------------------
# rglru_step — gated linear recurrence via log-space SCAN_ADD
# ---------------------------------------------------------------------------

RGLRU_T = 32   #: timesteps per block (one channel per block)


def rglru_step(T: int = RGLRU_T) -> Tuple[ir.Program, Callable]:
    """One RG-LRU chunk: ``h_t = a_t * h_{t-1} + x_t`` with pre-logged
    gates ``la_t = log a_t``, solved closed-form in log space —
    ``h_t = exp(cum_t) * (h0 + sum_{s<=t} exp(-cum_s) x_s)`` where
    ``cum`` is the inclusive ``SCAN_ADD`` of the log gates.  Exercises
    SCAN_ADD composed with EXP, the pattern ``rglru_scan_ref``'s
    ``lax.scan`` hides from the het core.
    """
    b = Builder("rglru_step", [Ptr("LA"), Ptr("Xv"), Ptr("H0"), Ptr("Hout")])
    c = b.block_id()
    tid = b.thread_id()
    tt = b.const(T)
    idx = c * tt + tid
    la = b.load("LA", idx)
    cum = b.scan_add(la)
    w = b.exp(b.const(0.0, ir.F32) - cum) * b.load("Xv", idx)
    ssum = b.scan_add(w)
    hv = b.exp(cum) * (b.load("H0", c) + ssum)
    b.store("Hout", idx, hv)
    prog = b.done()

    def oracle(args):
        LA = np.asarray(args["LA"], _F32)
        Xv = np.asarray(args["Xv"], _F32)
        H0 = np.asarray(args["H0"], _F32)
        C = LA.size // T
        out = np.array(args["Hout"], _F32)
        for c in range(C):
            la = LA[c * T:(c + 1) * T]
            xv = Xv[c * T:(c + 1) * T]
            cum = np.zeros(T, _F32)
            acc = _f32(0.0)
            for t in range(T):                 # lane-order inclusive scan
                acc = _f32(acc + la[t])
                cum[t] = acc
            w = exp_np(_f32(0.0) - cum) * xv
            ssum = np.zeros(T, _F32)
            acc = _f32(0.0)
            for t in range(T):
                acc = _f32(acc + w[t])
                ssum[t] = acc
            out[c * T:(c + 1) * T] = exp_np(cum) * (H0[c] + ssum)
        return {"Hout": out}

    return prog, oracle


# ---------------------------------------------------------------------------
# mlstm_cell — matrix-memory update + normalized read
# ---------------------------------------------------------------------------

MLSTM_D = 8   #: key/value dim; block = d*d threads, one per C entry


def mlstm_cell(d: int = MLSTM_D) -> Tuple[ir.Program, Callable]:
    """One mLSTM cell step (the inner recurrence of ``mlstm_chunk_ref``):
    matrix memory ``C' = f*C + i*(k (x) v)``, normalizer
    ``n' = f*n + i*k``, and the normalized read
    ``h = (q @ C') / max(|q . n'|, 1)``.  One thread per C entry
    (block = d*d); k/v/q are staged through shared memory and the
    stabilizer dot uses ``REDUCE_ADD`` with masked-to-zero lanes.
    """
    b = Builder("mlstm_cell",
                [Ptr("Q"), Ptr("K"), Ptr("V"), Ptr("Cin"), Ptr("Nin"),
                 Ptr("Cout"), Ptr("Nout"), Ptr("Hout"),
                 Scalar("fg", ir.F32), Scalar("ig", ir.F32)],
                shared_size=3 * d)
    assert d & (d - 1) == 0, "d must be a power of two (index math uses shifts)"
    shift = d.bit_length() - 1
    h = b.block_id()
    tid = b.thread_id()
    dd = b.const(d)
    fg = b.param("fg")
    ig = b.param("ig")
    row = tid >> b.const(shift)
    col = tid & b.const(d - 1)
    lane = b.select(tid < dd, tid, b.const(0))   # clamped d-range index
    with b.when(tid < dd):
        b.store_shared(tid, b.load("K", h * dd + tid))
        b.store_shared(dd + tid, b.load("V", h * dd + tid))
        b.store_shared(b.const(2 * d) + tid, b.load("Q", h * dd + tid))
    b.barrier("kvq-staged")
    ki = b.load_shared(row)
    vj = b.load_shared(dd + col)
    cidx = h * b.const(d * d) + tid              # == (h*d+row)*d+col
    cnew = fg * b.load("Cin", cidx) + ig * ki * vj
    b.store("Cout", cidx, cnew)
    nnew = fg * b.load("Nin", h * dd + lane) + ig * b.load_shared(lane)
    with b.when(tid < dd):
        b.store("Nout", h * dd + tid, nnew)
    qn = b.load_shared(b.const(2 * d) + lane) * nnew
    contrib = b.select(tid < dd, qn, b.const(0.0, ir.F32))
    den = b.maximum(b.abs(b.reduce_add(contrib)), b.const(1.0, ir.F32))
    b.barrier("c-flushed")
    num = b.var(b.const(0.0, ir.F32), hint="num")
    with b.loop(d, hint="ii") as ii:
        b.assign(num, num + b.load_shared(b.const(2 * d) + ii)
                 * b.load("Cout", (h * dd + ii) * dd + lane))
    with b.when(tid < dd):
        b.store("Hout", h * dd + tid, num / den)
    prog = b.done()

    def oracle(args):
        fg = _f32(args["fg"])
        ig = _f32(args["ig"])
        Q = np.asarray(args["Q"], _F32)
        K = np.asarray(args["K"], _F32)
        V = np.asarray(args["V"], _F32)
        H = Q.size // d
        Cin = np.asarray(args["Cin"], _F32).reshape(H, d, d)
        Nin = np.asarray(args["Nin"], _F32).reshape(H, d)
        Cout = np.array(args["Cout"], _F32).reshape(H, d, d)
        Nout = np.array(args["Nout"], _F32).reshape(H, d)
        Hout = np.array(args["Hout"], _F32).reshape(H, d)
        B = d * d
        for hh in range(H):
            q = Q[hh * d:(hh + 1) * d]
            k = K[hh * d:(hh + 1) * d]
            v = V[hh * d:(hh + 1) * d]
            ik = ig * k
            cnew = (fg * Cin[hh]) + ik[:, None] * v[None, :]
            Cout[hh] = cnew
            nnew = (fg * Nin[hh]) + ik
            Nout[hh] = nnew
            qn = q * nnew
            contrib = np.zeros(B, _F32)
            contrib[:d] = qn
            dot = np.zeros((), _F32)
            for t in range(B):                 # lane-order fold (incl. zeros)
                dot = np.add(dot, contrib[t], dtype=_F32)
            den = np.maximum(np.abs(dot), _f32(1.0))
            num = np.zeros(d, _F32)
            for ii in range(d):
                num = num + q[ii] * cnew[ii, :]
            Hout[hh] = num / den
        return {"Cout": Cout.reshape(-1), "Nout": Nout.reshape(-1),
                "Hout": Hout.reshape(-1)}

    return prog, oracle


# ---------------------------------------------------------------------------
# Canonical launches, EXAMPLES-style: name -> (grid, block, make_args, outs)
# ---------------------------------------------------------------------------

_ATTN_H = 4
_ATTN_NTILES = 3
_MOE_N = 64        # grid 4 x block 16
_RGLRU_C = 8
_MLSTM_H = 4


def _attn_args(rng):
    H, D, T, nt = _ATTN_H, ATTN_D, ATTN_T, _ATTN_NTILES
    S = nt * T
    return {
        "Q": rng.standard_normal(H * D).astype(_F32),
        "K": rng.standard_normal(H * S * D).astype(_F32),
        "V": rng.standard_normal(H * S * D).astype(_F32),
        "O": np.zeros(H * D, _F32),
        "ntiles": nt,
        "scale": _f32(1.0 / np.sqrt(D)),
    }


def _moe_args(rng):
    N, E, F = _MOE_N, MOE_E, MOE_F
    return {
        "X": rng.standard_normal(N * F).astype(_F32),
        "Wg": rng.standard_normal(E * F).astype(_F32),
        "We": rng.standard_normal(E * F * F).astype(_F32),
        "Y": np.zeros(N * F, _F32),
        "Eidx": np.zeros(N, np.int32),
        "E": E,
        "F": F,
    }


def _rglru_args(rng):
    C, T = _RGLRU_C, RGLRU_T
    return {
        # log gates in [-0.5, -0.01]: decaying memory, exp() well-conditioned
        "LA": (-(rng.random(C * T) * 0.49 + 0.01)).astype(_F32),
        "Xv": rng.standard_normal(C * T).astype(_F32),
        "H0": rng.standard_normal(C).astype(_F32),
        "Hout": np.zeros(C * T, _F32),
    }


def _mlstm_args(rng):
    H, d = _MLSTM_H, MLSTM_D
    return {
        "Q": rng.standard_normal(H * d).astype(_F32),
        "K": rng.standard_normal(H * d).astype(_F32),
        "V": rng.standard_normal(H * d).astype(_F32),
        "Cin": rng.standard_normal(H * d * d).astype(_F32),
        "Nin": rng.standard_normal(H * d).astype(_F32),
        "Cout": np.zeros(H * d * d, _F32),
        "Nout": np.zeros(H * d, _F32),
        "Hout": np.zeros(H * d, _F32),
        "fg": _f32(0.9),
        "ig": _f32(0.4),
    }


ZOO: Dict[str, Callable] = {
    "attn_decode": attn_decode,
    "moe_route_gmm": moe_route_gmm,
    "rglru_step": rglru_step,
    "mlstm_cell": mlstm_cell,
}

ZOO_EXAMPLES: Dict[str, tuple] = {
    "attn_decode": (_ATTN_H, ATTN_T, _attn_args, ("O",)),
    "moe_route_gmm": (4, 16, _moe_args, ("Y", "Eidx")),
    "rglru_step": (_RGLRU_C, RGLRU_T, _rglru_args, ("Hout",)),
    "mlstm_cell": (_MLSTM_H, MLSTM_D * MLSTM_D, _mlstm_args,
                   ("Cout", "Nout", "Hout")),
}

for _name, _builder in ZOO.items():
    register_kernel(_name, _builder, ZOO_EXAMPLES[_name], registry="zoo")
