"""Model zoo: real attention/MoE/recurrent kernels as hetIR modules.

Importing this package registers the four zoo kernels under the
``"zoo"`` namespace of :mod:`repro.core.kernels_suite`, making them
reachable through ``example_launch``/``lookup``/``registered_examples``
exactly like the built-in suite.
"""
from .kernels import (  # noqa: F401
    ZOO,
    ZOO_EXAMPLES,
    ATTN_D,
    ATTN_T,
    MOE_E,
    MOE_F,
    RGLRU_T,
    MLSTM_D,
    attn_decode,
    moe_route_gmm,
    rglru_step,
    mlstm_cell,
)

__all__ = [
    "ZOO", "ZOO_EXAMPLES", "ATTN_D", "ATTN_T", "MOE_E", "MOE_F",
    "RGLRU_T", "MLSTM_D", "attn_decode", "moe_route_gmm", "rglru_step",
    "mlstm_cell",
]
