"""Model primitives: norms, RoPE, GQA attention (full/windowed, chunked),
SwiGLU/GELU FFN, sort-based MoE, RG-LRU, mLSTM, sLSTM.

Everything is a pure function over dict-pytree params.  Attention uses
online-softmax q-chunking (flash-style in pure jnp) so the 32k prefill
shapes never materialize an S×S score matrix — the Pallas flash kernel in
``repro.kernels`` replaces the inner loop on real TPU.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_norm(x, p, cfg: ModelConfig):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype),
            "bias": jnp.zeros((cfg.d_model,), dtype)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-math.log(theta)
                   * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "wq": jax.random.normal(k1, (d, h * hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, hkv * hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, hkv * hd), dtype) * s,
        "wo": jax.random.normal(k4, (h * hd, d), dtype) * s,
    }


import functools as _functools


def _attn_probs(qc, k, c0, *, causal, window, q_offset, scale, Sk):
    """Normalized attention probabilities for one q chunk (f32)."""
    s = jnp.einsum("bchd,bshd->bhcs", qc.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = c0 + jnp.arange(qc.shape[1]) + q_offset     # [C]
    kpos = jnp.arange(Sk)                               # [Sk]
    mask = jnp.ones((qc.shape[1], Sk), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return p / l


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _mha_chunked(q, k, v, causal: bool, window: Optional[int],
                 q_offset: int = 0, q_chunk: int = 512):
    """Online q-chunked attention, flat heads.  q/k/v: [B,S,H,hd] (GQA kv
    pre-repeated so the head dim TP-shards cleanly).  Never materializes
    Sq×Sk; the custom VJP recomputes probabilities chunk-by-chunk so the
    backward never stores them either (flash-style backward in jnp —
    §Perf llama3-405b iteration 3)."""
    out, _ = _mha_chunked_fwd(q, k, v, causal, window, q_offset, q_chunk)
    return out


def _mha_chunked_fwd(q, k, v, causal, window, q_offset, q_chunk):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)

    def attend_chunk(qc, c0):
        p = _attn_probs(qc, k, c0, causal=causal, window=window,
                        q_offset=q_offset, scale=scale, Sk=Sk)
        return jnp.einsum("bhcs,bshd->bchd", p, v.astype(jnp.float32))

    if Sq <= q_chunk:
        out = attend_chunk(q, 0)
    else:
        n = Sq // q_chunk
        assert Sq % q_chunk == 0, "seq_len must be divisible by q_chunk"
        qs = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)

        def body(c, qc):
            return c + 1, attend_chunk(qc, c * q_chunk)

        _, outs = jax.lax.scan(body, 0, qs)
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype), (q, k, v)


def _mha_chunked_bwd(causal, window, q_offset, q_chunk, res, do):
    q, k, v = res
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    n = max(Sq // q_chunk, 1)
    cq = Sq // n
    qs = q.reshape(B, n, cq, H, hd).transpose(1, 0, 2, 3, 4)
    dos = do.reshape(B, n, cq, H, hd).transpose(1, 0, 2, 3, 4) \
        .astype(jnp.float32)

    def body(carry, inp):
        i, dk, dv = carry
        qc, doc = inp
        c0 = i * cq
        p = _attn_probs(qc, k, c0, causal=causal, window=window,
                        q_offset=q_offset, scale=scale, Sk=Sk)
        # dv += p^T do ; dp = do v^T ; ds = p*(dp - rowsum(p*dp))
        dv = dv + jnp.einsum("bhcs,bchd->bshd", p, doc)
        dp = jnp.einsum("bchd,bshd->bhcs", doc, vf)
        row = jnp.sum(p * dp, axis=-1, keepdims=True)
        ds = p * (dp - row)
        dqc = jnp.einsum("bhcs,bshd->bchd", ds, kf) * scale
        dk = dk + jnp.einsum("bhcs,bchd->bshd", ds,
                             qc.astype(jnp.float32)) * scale
        return (i + 1, dk, dv), dqc

    zeros = jnp.zeros((B, Sk, H, hd), jnp.float32)
    (_, dk, dv), dqs = jax.lax.scan(body, (0, zeros, zeros), (qs, dos))
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_mha_chunked.defvjp(lambda q, k, v, c, w, o, qc:
                    _mha_chunked_fwd(q, k, v, c, w, o, qc),
                    _mha_chunked_bwd)


def _repeat_kv(k, n_heads: int):
    """[B,S,Hkv,hd] -> [B,S,H,hd] (GQA repeat; h = kv*G + g)."""
    G = n_heads // k.shape[2]
    return jnp.repeat(k, G, axis=2) if G > 1 else k


def attention(x, p, cfg: ModelConfig, *, causal: bool = True,
              window: Optional[int] = None, positions=None,
              kv_override: Optional[Tuple] = None, ac=None):
    """Self-attention over x [B,S,D] (kv_override -> cross-attention)."""
    ac = ac or (lambda t, kind: t)
    x = ac(x, "mm_input")
    B, S, D = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(B, S, hkv, hd)
        v = (x @ p["wv"]).reshape(B, S, hkv, hd)
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        q_offset = 0
    else:
        k, v = kv_override
        q_offset = 0
        causal, window = False, None
    q = ac(q, "heads4")
    k = ac(_repeat_kv(k, h), "heads4")
    v = ac(_repeat_kv(v, h), "heads4")
    if cfg.attn_vjp == "flash":
        o = _mha_chunked(q, k, v, causal, window, q_offset)
    else:  # baseline: plain autodiff through the chunk scan
        o, _ = _mha_chunked_fwd(q, k, v, causal, window, q_offset, 512)
        o = o.astype(q.dtype)
    o = ac(o.reshape(B, S, h * hd), "attn_mix")
    return o @ p["wo"]


def attention_decode(x, p, cfg: ModelConfig, cache, pos, *,
                     window: Optional[int] = None):
    """One-token decode against a cache.

    cache: {"k","v": [B, S_cache, Hkv, hd]} — ring buffer when windowed.
    pos: absolute position (scalar int32) of the new token.
    """
    B, S, D = x.shape  # S == 1
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, 1, h, hd)
    k_new = (x @ p["wk"]).reshape(B, 1, hkv, hd)
    v_new = (x @ p["wv"]).reshape(B, 1, hkv, hd)
    posv = jnp.full((B, 1), pos)
    q = rope(q, posv, cfg.rope_theta)
    k_new = rope(k_new, posv, cfg.rope_theta)

    S_cache = cache["k"].shape[1]
    slot = pos % S_cache if window is not None else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(
        cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(
        cache["v"].dtype), (0, slot, 0, 0))
    new_cache = {"k": k, "v": v}

    # positions of cache slots
    if window is not None:
        # ring buffer: slot i holds position  i + floor((pos - i)/S)*S ...
        idx = jnp.arange(S_cache)
        base = pos - ((pos - idx) % S_cache)
        kpos = base
        valid = (kpos >= 0) & (kpos >= pos - window + 1) & (kpos <= pos)
    else:
        idx = jnp.arange(S_cache)
        kpos = idx
        valid = idx <= pos

    scale = 1.0 / math.sqrt(hd)
    G = h // hkv
    qh = q.reshape(B, 1, hkv, G, hd)
    s = jnp.einsum("bckgh,bskh->bkgcs", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
    pbar = jnp.exp(s - m)
    l = jnp.sum(pbar, axis=-1, keepdims=True)
    o = jnp.einsum("bkgcs,bskh->bckgh", pbar / jnp.maximum(l, 1e-30),
                   v.astype(jnp.float32))
    o = o.reshape(B, 1, h * hd).astype(x.dtype)
    return o @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, d_ff: int, dtype) -> Params:
    d = cfg.d_model
    s = 1.0 / math.sqrt(d)
    if cfg.act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"wg": jax.random.normal(k1, (d, d_ff), dtype) * s,
                "wu": jax.random.normal(k2, (d, d_ff), dtype) * s,
                "wd": jax.random.normal(k3, (d_ff, d), dtype)
                / math.sqrt(d_ff)}
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (d, d_ff), dtype) * s,
            "w2": jax.random.normal(k2, (d_ff, d), dtype)
            / math.sqrt(d_ff)}


def ffn(x, p, cfg: ModelConfig, ac=None):
    ac = ac or (lambda t, kind: t)
    x = ac(x, "mm_input")
    if cfg.act == "swiglu":
        h = ac(jax.nn.silu(x @ p["wg"]) * (x @ p["wu"]), "ffn_hidden")
        return h @ p["wd"]
    h = ac(jax.nn.gelu(x @ p["w1"]), "ffn_hidden")
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# MoE (sort-based capacity dispatch — EP/expert-TP shardable)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {"router": jax.random.normal(k1, (d, e), dtype) * s}
    if cfg.act == "swiglu":
        p["wg"] = jax.random.normal(k2, (e, d, f), dtype) * s
        p["wu"] = jax.random.normal(k3, (e, d, f), dtype) * s
        p["wd"] = jax.random.normal(k4, (e, f, d), dtype) / math.sqrt(f)
    else:
        p["w1"] = jax.random.normal(k2, (e, d, f), dtype) * s
        p["w2"] = jax.random.normal(k3, (e, f, d), dtype) / math.sqrt(f)
    return p


def moe_ffn(x, p, cfg: ModelConfig, ac=None):
    if cfg.moe_impl == "grouped":
        return moe_ffn_grouped(x, p, cfg, ac)
    return _moe_ffn_global(x, p, cfg, ac)


def _moe_ffn_global(x, p, cfg: ModelConfig, ac=None):
    """Sort-based top-k MoE with static capacity (tokens over capacity are
    dropped, matching capacity-factor semantics).  x: [B,S,D].
    BASELINE formulation: one global sort/scatter over all tokens — the
    data-dependent global scatter forces GSPMD into full-size all-reduces
    (see EXPERIMENTS.md §Perf mixtral iteration 1)."""
    ac = ac or (lambda t, kind: t)
    x = ac(x, "mm_input")
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = int(math.ceil(T * K / E * m.capacity_factor))
    C = max(1, min(C, T))

    xf = x.reshape(T, D)
    logits = (xf @ p["router"]).astype(jnp.float32)          # [T,E]
    gates, idx = jax.lax.top_k(logits, K)                    # [T,K]
    gates = jax.nn.softmax(gates, axis=-1)

    flat_e = idx.reshape(-1)                                  # [T*K]
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    tok = order // K                                          # token of entry
    # rank within expert
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))        # [E]
    rank = jnp.arange(T * K) - starts[sorted_e]
    keep = rank < C
    slot_e = jnp.where(keep, sorted_e, E)                     # drop -> OOB
    slot_c = jnp.where(keep, rank, 0)

    buf = jnp.zeros((E, C, D), xf.dtype)
    buf = buf.at[slot_e, slot_c].set(xf[tok], mode="drop")
    buf = ac(buf, "moe_buf")

    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
        h = ac(h * jnp.einsum("ecd,edf->ecf", buf, p["wu"]), "moe_hidden")
        out_buf = ac(jnp.einsum("ecf,efd->ecd", h, p["wd"]), "moe_buf")
    else:
        h = ac(jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])),
               "moe_hidden")
        out_buf = ac(jnp.einsum("ecf,efd->ecd", h, p["w2"]), "moe_buf")

    # gather back and combine with gate weights
    gathered = out_buf[jnp.minimum(sorted_e, E - 1), slot_c]  # [T*K, D]
    w = gates.reshape(-1)[order] * keep.astype(gates.dtype)
    contrib = gathered * w[:, None].astype(gathered.dtype)
    out = jnp.zeros((T, D), contrib.dtype).at[tok].add(contrib)
    return out.reshape(B, S, D).astype(x.dtype)


def moe_ffn_grouped(x, p, cfg: ModelConfig, ac=None):
    """Group-local MoE dispatch (§Perf): routing, sort, capacity, scatter
    and combine all happen WITHIN a batch row, so when the batch dim is
    data-sharded every index operation is shard-local — no cross-device
    scatter, no token all-reduces.  Capacity is enforced per row
    (group-limited routing, as in production JAX MoE stacks).

    The only cross-device communication left is the expert weight path
    (EP when n_experts divides the axis, expert-TP otherwise).
    """
    ac = ac or (lambda t, kind: t)
    x = ac(x, "mm_input")
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    C = int(math.ceil(S * K / E * m.capacity_factor))
    C = max(1, min(C, S * K))

    logits = (x @ p["router"]).astype(jnp.float32)           # [B,S,E]
    gates, idx = jax.lax.top_k(logits, K)                    # [B,S,K]
    gates = jax.nn.softmax(gates, axis=-1)

    SK = S * K
    flat_e = idx.reshape(B, SK)
    order = jnp.argsort(flat_e, axis=1)                      # [B,SK]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    tok = order // K                                          # [B,SK]
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(
        sorted_e)                                             # [B,E]
    rank = jnp.arange(SK)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=1)
    keep = rank < C
    slot_e = jnp.where(keep, sorted_e, E)                     # OOB -> drop
    slot_c = jnp.where(keep, rank, 0)

    xg = jnp.take_along_axis(x, tok[..., None], axis=1)       # [B,SK,D]
    brow = jnp.arange(B)[:, None] * jnp.ones((1, SK), jnp.int32)
    buf = jnp.zeros((B, E, C, D), x.dtype)
    buf = buf.at[brow, slot_e, slot_c].set(xg, mode="drop")
    buf = ac(buf, "moe_buf4")

    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wg"]))
        h = ac(h * jnp.einsum("becd,edf->becf", buf, p["wu"]),
               "moe_hidden4")
        out_buf = ac(jnp.einsum("becf,efd->becd", h, p["wd"]), "moe_buf4")
    else:
        h = ac(jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, p["w1"])),
               "moe_hidden4")
        out_buf = ac(jnp.einsum("becf,efd->becd", h, p["w2"]), "moe_buf4")

    gathered = out_buf[brow, jnp.minimum(sorted_e, E - 1), slot_c]
    w = jnp.take_along_axis(gates.reshape(B, SK), order, axis=1) \
        * keep.astype(gates.dtype)
    contrib = gathered * w[..., None].astype(gathered.dtype)
    out = jnp.zeros((B, S, D), contrib.dtype)
    out = out.at[brow, tok].add(contrib)
    return out.astype(x.dtype)


def moe_aux_loss(x, p, cfg: ModelConfig):
    """Load-balancing auxiliary loss (Switch-style)."""
    m = cfg.moe
    T = x.shape[0] * x.shape[1]
    logits = (x.reshape(T, -1) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(logits, m.top_k)
    counts = jnp.zeros((m.n_experts,), jnp.float32).at[idx.reshape(-1)].add(
        1.0)
    frac_tokens = counts / (T * m.top_k)
    frac_probs = probs.mean(axis=0)
    return m.n_experts * jnp.sum(frac_tokens * frac_probs)


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / recurrentgemma)
# ---------------------------------------------------------------------------


def init_rglru(key, cfg: ModelConfig, dtype) -> Params:
    d, dr, cw = cfg.d_model, cfg.d_rnn or cfg.d_model, cfg.conv_width
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    sr = 1.0 / math.sqrt(dr)
    return {
        "w_in_rec": jax.random.normal(ks[0], (d, dr), dtype) * s,
        "w_in_gate": jax.random.normal(ks[1], (d, dr), dtype) * s,
        "w_out": jax.random.normal(ks[2], (dr, d), dtype) * sr,
        "conv_w": jax.random.normal(ks[3], (cw, dr), dtype) * 0.1,
        "w_r": jax.random.normal(ks[4], (dr, dr), dtype) * sr,
        "w_i": jax.random.normal(ks[5], (dr, dr), dtype) * sr,
        "lam": jnp.full((dr,), 4.0, dtype),  # sigmoid(4)≈0.98 slow decay
    }


_RG_C = 8.0


def _rglru_gates(u, p):
    r = jax.nn.sigmoid((u @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32))
    log_a_base = -jax.nn.softplus(-p["lam"].astype(jnp.float32))  # log σ(Λ)
    log_a = _RG_C * r * log_a_base[None, ...]
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * i


def _causal_conv(u, w, state=None):
    """Depthwise causal conv along time.  u: [B,S,dr], w: [cw,dr].
    state: [B,cw-1,dr] carried tail for decode."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(cw))
    new_state = full[:, -(cw - 1):] if cw > 1 else None
    return out, new_state


def rglru(x, p, cfg: ModelConfig, state=None, ac=None):
    """x: [B,S,D] -> y [B,S,D].  state: {"h": [B,dr], "conv": [B,cw-1,dr]}."""
    ac = ac or (lambda t, kind: t)
    x = ac(x, "mm_input")
    B, S, D = x.shape
    u = ac(x @ p["w_in_rec"], "ffn_hidden")
    gate = ac(jax.nn.gelu(x @ p["w_in_gate"]), "ffn_hidden")
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u, p["conv_w"], conv_state)
    a, b = _rglru_gates(u, p)     # [B,S,dr] f32
    bu = b * u.astype(jnp.float32)

    h0 = jnp.zeros((B, u.shape[-1]), jnp.float32) if state is None \
        else state["h"].astype(jnp.float32)

    def step(h, inputs):
        a_t, bu_t = inputs
        h = a_t * h + bu_t
        return h, h

    hT, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2),
                                     bu.transpose(1, 0, 2)))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    y = (h * gate) @ p["w_out"]
    new_state = {"h": hT, "conv": new_conv} if new_conv is not None else \
        {"h": hT, "conv": jnp.zeros((B, 0, u.shape[-1]), x.dtype)}
    return y, new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    de = 2 * d
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "w_qkv": jax.random.normal(ks[0], (d, 3 * de), dtype) * s,
        "w_o": jax.random.normal(ks[1], (de, d), dtype) / math.sqrt(de),
        "w_if": jax.random.normal(ks[2], (d, 2 * cfg.n_heads), dtype) * s,
        "w_skip": jax.random.normal(ks[3], (d, de), dtype) * s,
    }


def mlstm(x, p, cfg: ModelConfig, state=None, ac=None):
    """Stabilized mLSTM.  state: {"C":[B,H,hk,hv],"n":[B,H,hk],
    "m":[B,H]}.  Dispatches to the chunked form for full sequences when
    ``cfg.mlstm_impl == "chunked"`` (decode stays per-step)."""
    if cfg.mlstm_impl == "chunked" and x.shape[1] > 1:
        return mlstm_chunked(x, p, cfg, state, ac=ac,
                             chunk=cfg.mlstm_chunk)
    return _mlstm_scan(x, p, cfg, state, ac=ac)


def _mlstm_scan(x, p, cfg: ModelConfig, state=None, ac=None):
    ac = ac or (lambda t, kind: t)
    x = ac(x, "mm_input")
    B, S, D = x.shape
    H = cfg.n_heads
    de = 2 * D
    hd = de // H
    qkv = ac(x @ p["w_qkv"], "ffn_hidden")
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, hd) / math.sqrt(hd)
    k = k.reshape(B, S, H, hd) / math.sqrt(hd)
    v = v.reshape(B, S, H, hd)
    gates = (x @ p["w_if"]).astype(jnp.float32).reshape(B, S, 2, H)
    log_i = -jax.nn.softplus(-gates[:, :, 0])   # log σ(i)
    log_f = -jax.nn.softplus(-gates[:, :, 1])   # log σ(f)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = (state["C"].astype(jnp.float32),
                      state["n"].astype(jnp.float32),
                      state["m"].astype(jnp.float32))

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, li, lf = inp   # [B,H,hd] ×3, [B,H] ×2
        m_new = jnp.maximum(lf + m, li)
        f_sc = jnp.exp(lf + m - m_new)[..., None]
        i_sc = jnp.exp(li - m_new)[..., None]
        C = f_sc[..., None] * C + i_sc[..., None] * (
            k_t[..., :, None] * v_t[..., None, :])
        n = f_sc * n + i_sc * k_t
        num = jnp.einsum("bhk,bhkv->bhv", q_t, C)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", q_t, n))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    xs = (q.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          log_i.transpose(1, 0, 2), log_f.transpose(1, 0, 2))
    (CT, nT, mT), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, de).astype(x.dtype)
    skip = jax.nn.silu(x @ p["w_skip"])
    y = (h * skip) @ p["w_o"]
    return y, {"C": CT, "n": nT, "m": mT}


def mlstm_chunked(x, p, cfg: ModelConfig, state=None, ac=None,
                  chunk: int = 128):
    """Chunked stabilized mLSTM — same semantics as :func:`_mlstm_scan`
    but the matrix state only crosses HBM once per *chunk* instead of once
    per *token* (the §Perf fix for the xlstm memory roofline; mirrors the
    ``mlstm_chunk`` Pallas kernel with running-max stabilization).

    Derivation (per head; m_in = carry max, Ĉ/n̂ stored pre-scaled):
        L[t]  = Σ_{u≤t} lf_u          (in-chunk cumulative log-forget)
        z[u]  = li_u − L[u]
        M[t]  = max(m_in, cummax z)   ;  m_t = L[t] + M[t]
        Ĉ_t  = e^{m_in−M[t]} Ĉ_in + Σ_{u≤t} e^{z[u]−M[t]} k_u v_uᵀ
        y_t   = q_t·Ĉ_t / max(|q_t·n̂_t|, e^{−m_t})
    All exponents are ≤ 0, so the chunk math is overflow-free.
    """
    ac = ac or (lambda t, kind: t)
    x = ac(x, "mm_input")
    B, S, D = x.shape
    H = cfg.n_heads
    de = 2 * D
    hd = de // H
    qkv = ac(x @ p["w_qkv"], "ffn_hidden")
    q, k, v = jnp.split(qkv, 3, axis=-1)
    scale = 1.0 / math.sqrt(hd)
    # [B,H,S,hd]
    q = (q.reshape(B, S, H, hd) * scale).transpose(0, 2, 1, 3) \
        .astype(jnp.float32)
    k = (k.reshape(B, S, H, hd) * scale).transpose(0, 2, 1, 3) \
        .astype(jnp.float32)
    v = v.reshape(B, S, H, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    gates = (x @ p["w_if"]).astype(jnp.float32).reshape(B, S, 2, H)
    log_i = -jax.nn.softplus(-gates[:, :, 0]).transpose(0, 2, 1)  # [B,H,S]
    log_f = -jax.nn.softplus(-gates[:, :, 1]).transpose(0, 2, 1)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = (state["C"].astype(jnp.float32),
                      state["n"].astype(jnp.float32),
                      state["m"].astype(jnp.float32))

    bt = min(chunk, S)
    assert S % bt == 0, "seq_len must divide the mLSTM chunk"
    nc = S // bt

    def to_chunks(t):  # [B,H,S,...] -> [nc,B,H,bt,...]
        return t.reshape(t.shape[:2] + (nc, bt) + t.shape[3:]) \
            .transpose(2, 0, 1, 3, *range(4, t.ndim + 1))

    xs = (to_chunks(q), to_chunks(k), to_chunks(v),
          to_chunks(log_f), to_chunks(log_i))

    def chunk_step(carry, inp):
        C, n, m_in = carry                     # [B,H,hd,hd],[B,H,hd],[B,H]
        qc, kc, vc, lf, li = inp               # [B,H,bt,(hd)]
        L = jnp.cumsum(lf, axis=-1)            # [B,H,bt]
        z = li - L
        g = jax.lax.cummax(z, axis=2)
        M = jnp.maximum(m_in[..., None], g)    # [B,H,bt]
        m_t = L + M

        inter_w = jnp.exp(m_in[..., None] - M)           # [B,H,bt]
        # intra decay matrix w[t,u] = e^{z[u] - M[t]} for u<=t
        wmat = jnp.exp(z[..., None, :] - M[..., :, None])
        tpos = jnp.arange(bt)
        causal = tpos[:, None] >= tpos[None, :]          # [t, u]
        wmat = jnp.where(causal[None, None], wmat, 0.0)  # [B,H,t,u]

        s = jnp.einsum("bhtd,bhud->bhtu", qc, kc)
        y_num = inter_w[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qc, C) \
            + jnp.einsum("bhtu,bhuv->bhtv", s * wmat, vc)
        # q_t·n̂_t = inter_w·(q_t·n̂_in) + Σ_{u≤t} (q_t·k_u)·w[t,u]
        qn = jnp.einsum("bhtd,bhd->bht", qc, n)
        den = jnp.abs(inter_w * qn + jnp.sum(s * wmat, axis=-1))
        h = y_num / jnp.maximum(den, jnp.exp(-m_t))[..., None]

        # chunk-end state
        w_end = jnp.exp(z - M[..., -1:])                 # [B,H,bt]
        C_out = inter_w[..., -1, None, None] * C + jnp.einsum(
            "bhud,bhuv->bhdv", kc * w_end[..., None], vc)
        n_out = inter_w[..., -1, None] * n + jnp.einsum(
            "bhud,bhu->bhd", kc, w_end)
        m_out = m_t[..., -1]
        return (C_out, n_out, m_out), h

    (CT, nT, mT), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    # hs: [nc,B,H,bt,hd] -> [B,S,de]
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd) \
        .transpose(0, 2, 1, 3).reshape(B, S, de).astype(x.dtype)
    skip = jax.nn.silu(x @ p["w_skip"])
    y = (h * skip) @ p["w_o"]
    return y, {"C": CT, "n": nT, "m": mT}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory)
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {
        "w_x": jax.random.normal(ks[0], (d, 4 * d), dtype) * s,
        # block-diagonal recurrent weights, per head
        "r": jax.random.normal(ks[1], (H, dh, 4 * dh), dtype)
        / math.sqrt(dh),
        "w_o": jax.random.normal(ks[2], (d, d), dtype) * s,
    }


def slstm(x, p, cfg: ModelConfig, state=None):
    """sLSTM with exponential gating.  state: {"c","n","h":[B,D],
    "m":[B,D]}."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    zx = x @ p["w_x"]   # [B,S,4D]

    if state is None:
        c0 = jnp.zeros((B, D), jnp.float32)
        n0 = jnp.ones((B, D), jnp.float32)
        h0 = jnp.zeros((B, D), jnp.float32)
        m0 = jnp.zeros((B, D), jnp.float32)
    else:
        c0, n0, h0, m0 = (state[k].astype(jnp.float32)
                          for k in ("c", "n", "h", "m"))

    # recurrent weights laid out gate-major to match w_x's [4*D] layout
    r = p["r"].astype(jnp.float32).reshape(H, dh, 4, dh)

    def step(carry, zx_t):
        c, n, h, m = carry
        hh = h.reshape(B, H, dh)
        zr = jnp.einsum("bhk,hkgj->bghj", hh, r).reshape(B, 4 * D)
        z = zx_t.astype(jnp.float32) + zr
        zi, zf, zz, zo = jnp.split(z, 4, axis=-1)
        m_new = jnp.maximum(zf + m, zi)
        i = jnp.exp(zi - m_new)
        f = jnp.exp(zf + m - m_new)
        c = f * c + i * jnp.tanh(zz)
        n = f * n + i
        h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    (cT, nT, hT, mT), hs = jax.lax.scan(step, (c0, n0, h0, m0),
                                        zx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype) @ p["w_o"]
    return y, {"c": cT, "n": nT, "h": hT, "m": mT}
