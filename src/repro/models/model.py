"""Model assembly: layer-group scan, caches, train/prefill/decode entry
points for all 10 assigned architectures.

Layer stacks are ``lax.scan``-stacked by group (see configs.base) so the
compiled HLO stays compact for the 512-device dry-run.  ``ac`` is an
optional activation-constraint hook installed by ``repro.parallel`` to pin
shardings on the residual stream / logits.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, DENSE_FFN, MLSTM, MOE_FFN, NO_FFN,
                                RGLRU, SLSTM, SWA, BlockSpec, ModelConfig)
from . import layers as L

Params = Dict[str, Any]
_ID_AC = lambda x, kind: x  # noqa: E731


def _dt(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, spec: BlockSpec) -> Params:
    dtype = _dt(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": L.init_norm(cfg, dtype)}
    if spec.mixer in (ATTN, SWA):
        p["mixer"] = L.init_attention(ks[0], cfg, dtype)
    elif spec.mixer == RGLRU:
        p["mixer"] = L.init_rglru(ks[0], cfg, dtype)
    elif spec.mixer == MLSTM:
        p["mixer"] = L.init_mlstm(ks[0], cfg, dtype)
    elif spec.mixer == SLSTM:
        p["mixer"] = L.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        p["norm_cross"] = L.init_norm(cfg, dtype)
        p["cross"] = L.init_attention(ks[1], cfg, dtype)
    if spec.ffn == DENSE_FFN:
        p["norm2"] = L.init_norm(cfg, dtype)
        p["ffn"] = L.init_ffn(ks[2], cfg, cfg.d_ff, dtype)
    elif spec.ffn == MOE_FFN:
        p["norm2"] = L.init_norm(cfg, dtype)
        p["ffn"] = L.init_moe(ks[2], cfg, dtype)
    return p


def _init_groups(key, cfg: ModelConfig, groups) -> list:
    out = []
    gkeys = jax.random.split(key, max(len(groups), 1))
    for (repeat, body), gk in zip(groups, gkeys):
        bkeys = jax.random.split(gk, repeat)

        def one(k, body=body):
            ks = jax.random.split(k, len(body))
            return {f"slot{i}": init_block(ks[i], cfg, spec)
                    for i, spec in enumerate(body)}

        out.append(jax.vmap(one)(bkeys))
    return out


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = _dt(cfg.param_dtype)
    k_emb, k_head, k_groups, k_enc, k_front = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(cfg.d_model)
    params: Params = {
        "embed": jax.random.normal(k_emb, (cfg.padded_vocab, cfg.d_model),
                                   dtype) * s,
        "final_norm": L.init_norm(cfg, dtype),
        "groups": _init_groups(k_groups, cfg, cfg.layer_groups),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            k_head, (cfg.d_model, cfg.padded_vocab), dtype) * s
    if cfg.frontend != "none":
        params["frontend"] = {"proj": jax.random.normal(
            k_front, (cfg.d_model, cfg.d_model), dtype) * s}
    if cfg.encoder_decoder:
        enc_spec = BlockSpec(mixer=ATTN, ffn=DENSE_FFN)
        params["enc"] = {
            "groups": _init_groups(k_enc, cfg,
                                   ((cfg.enc_layers, (enc_spec,)),)),
            "final_norm": L.init_norm(cfg, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# block application (train / prefill)
# ---------------------------------------------------------------------------


def _mixer_full(x, bp, spec, cfg, positions, causal, state=None,
                ac: Callable = _ID_AC):
    """Full-sequence mixer; returns (y, cache_entry_or_None)."""
    h = L.apply_norm(x, bp["norm1"], cfg)
    if spec.mixer in (ATTN, SWA):
        win = cfg.window if spec.mixer == SWA else None
        y = L.attention(h, bp["mixer"], cfg, causal=causal, window=win,
                        positions=positions, ac=ac)
        return y, None
    if spec.mixer == RGLRU:
        return L.rglru(h, bp["mixer"], cfg, state, ac=ac)
    if spec.mixer == MLSTM:
        return L.mlstm(h, bp["mixer"], cfg, state, ac=ac)
    if spec.mixer == SLSTM:
        return L.slstm(h, bp["mixer"], cfg, state)
    raise ValueError(spec.mixer)


def block_apply(x, bp, spec: BlockSpec, cfg: ModelConfig, positions,
                enc_out=None, ac: Callable = _ID_AC, causal: bool = True,
                aux: Optional[list] = None):
    y, _ = _mixer_full(x, bp, spec, cfg, positions, causal, ac=ac)
    x = ac(x + y, "residual")
    if spec.cross_attn and enc_out is not None:
        h = L.apply_norm(x, bp["norm_cross"], cfg)
        kv = _cross_kv(enc_out, bp["cross"], cfg)
        y = L.attention(h, bp["cross"], cfg, kv_override=kv, ac=ac)
        x = ac(x + y, "residual")
    if spec.ffn == DENSE_FFN:
        h = L.apply_norm(x, bp["norm2"], cfg)
        x = ac(x + L.ffn(h, bp["ffn"], cfg, ac=ac), "residual")
    elif spec.ffn == MOE_FFN:
        h = L.apply_norm(x, bp["norm2"], cfg)
        if aux is not None:
            aux.append(L.moe_aux_loss(h, bp["ffn"], cfg))
        x = ac(x + L.moe_ffn(h, bp["ffn"], cfg, ac=ac), "residual")
    return x


def _cross_kv(enc_out, p, cfg: ModelConfig):
    B, S, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return k, v


def _apply_groups(x, groups_params, groups_cfg, cfg: ModelConfig, positions,
                  enc_out=None, ac: Callable = _ID_AC, causal: bool = True,
                  remat: bool = True, aux_box: Optional[list] = None):
    for (repeat, body), gp in zip(groups_cfg, groups_params):

        def body_fn(xc, slot_params, body=body):
            aux = [] if aux_box is not None else None
            for i, spec in enumerate(body):
                xc = block_apply(xc, slot_params[f"slot{i}"], spec, cfg,
                                 positions, enc_out, ac, causal, aux)
            a = (jnp.stack(aux).sum() if aux else
                 jnp.zeros((), jnp.float32))
            return xc, a

        f = jax.checkpoint(body_fn) if remat else body_fn
        x, auxs = jax.lax.scan(f, x, gp)
        if aux_box is not None:
            aux_box.append(auxs.sum())
    return x


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return x.astype(_dt(cfg.compute_dtype))


def _logits(params, x, cfg: ModelConfig, ac: Callable = _ID_AC):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # force the unembedding weight gathered over the FSDP axis (kept
    # vocab-sharded over TP): otherwise GSPMD resolves the data-axis
    # conflict (batch on x vs d_model on w) by replicating the batch and
    # partial-summing f32 logits — orders of magnitude more traffic.
    w = ac(w.astype(x.dtype), "lm_head_weight")
    return ac((x @ w).astype(jnp.float32), "logits")


def _assemble_input(params, batch, cfg: ModelConfig):
    """tokens (+ stub-frontend embeds) -> (x [B,S,D], positions, loss_mask)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg)
    B, S_text = tokens.shape
    if cfg.frontend != "none" and "embeds" in batch and not \
            cfg.encoder_decoder:
        e = batch["embeds"].astype(x.dtype) @ params["frontend"]["proj"] \
            .astype(x.dtype)
        x = jnp.concatenate([e, x], axis=1)
        F = e.shape[1]
        mask = jnp.concatenate([jnp.zeros((B, F), jnp.float32),
                                jnp.ones((B, S_text), jnp.float32)], axis=1)
    else:
        mask = jnp.ones((B, S_text), jnp.float32)
    positions = jnp.arange(x.shape[1])[None, :]
    return x, positions, mask


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------


def forward_train(params, batch, cfg: ModelConfig, *, ac: Callable = _ID_AC,
                  remat: bool = True) -> jnp.ndarray:
    """Next-token CE loss (+ MoE aux loss).  batch: {"tokens": [B,S]} plus
    "embeds" for stub frontends / "enc_embeds" for enc-dec."""
    aux_box: list = [] if cfg.moe is not None else None

    if cfg.encoder_decoder:
        enc_x = (batch["enc_embeds"].astype(_dt(cfg.compute_dtype))
                 @ params["frontend"]["proj"].astype(
                     _dt(cfg.compute_dtype)))
        enc_pos = jnp.arange(enc_x.shape[1])[None, :]
        enc_spec_groups = ((cfg.enc_layers,
                            (BlockSpec(mixer=ATTN, ffn=DENSE_FFN),)),)
        enc_out = _apply_groups(enc_x, params["enc"]["groups"],
                                enc_spec_groups, cfg, enc_pos,
                                causal=False, remat=remat, ac=ac)
        enc_out = L.apply_norm(enc_out, params["enc"]["final_norm"], cfg)
        x, positions, mask = _assemble_input(params, batch, cfg)
        x = ac(x, "residual")
        x = _apply_groups(x, params["groups"], cfg.layer_groups, cfg,
                          positions, enc_out=enc_out, remat=remat,
                          aux_box=aux_box, ac=ac)
    else:
        x, positions, mask = _assemble_input(params, batch, cfg)
        x = ac(x, "residual")
        x = _apply_groups(x, params["groups"], cfg.layer_groups, cfg,
                          positions, remat=remat, aux_box=aux_box, ac=ac)

    x = L.apply_norm(x, params["final_norm"], cfg)
    # predict next token for the text region only
    F = x.shape[1] - batch["tokens"].shape[1]
    xt = x[:, F:, :]
    logits = _logits(params, ac(xt[:, :-1], "residual"), cfg)
    labels = batch["tokens"][:, 1:]
    lmask = mask[:, F + 1:]
    # vocab-shard-safe CE: no gather along the (TP-sharded) vocab dim —
    # logsumexp and the label logit both reduce over vocab shard-locally
    # and combine with a small psum under GSPMD.
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.sum(logits * onehot, axis=-1)
    nll = lse - label_logit
    loss = (nll * lmask).sum() / jnp.maximum(lmask.sum(), 1.0)
    if aux_box:
        loss = loss + 0.01 * sum(aux_box) / max(len(aux_box), 1)
    return loss


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _cache_len(cfg: ModelConfig, spec: BlockSpec, seq_len: int) -> int:
    if spec.mixer == SWA and cfg.window is not None:
        return min(cfg.window, seq_len)
    return seq_len


def init_block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     seq_len: int, enc_len: int = 0,
                     dtype=None) -> Params:
    dtype = dtype or _dt(cfg.compute_dtype)
    hkv, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    c: Params = {}
    if spec.mixer in (ATTN, SWA):
        Lc = _cache_len(cfg, spec, seq_len)
        c["k"] = jnp.zeros((batch, Lc, hkv, hd), dtype)
        c["v"] = jnp.zeros((batch, Lc, hkv, hd), dtype)
    elif spec.mixer == RGLRU:
        dr = cfg.d_rnn or cfg.d_model
        c["h"] = jnp.zeros((batch, dr), jnp.float32)
        c["conv"] = jnp.zeros((batch, cfg.conv_width - 1, dr), dtype)
    elif spec.mixer == MLSTM:
        de = 2 * cfg.d_model
        hdm = de // H
        c["C"] = jnp.zeros((batch, H, hdm, hdm), jnp.float32)
        c["n"] = jnp.zeros((batch, H, hdm), jnp.float32)
        c["m"] = jnp.full((batch, H), -1e30, jnp.float32)
    elif spec.mixer == SLSTM:
        D = cfg.d_model
        c["c"] = jnp.zeros((batch, D), jnp.float32)
        c["n"] = jnp.ones((batch, D), jnp.float32)
        c["h"] = jnp.zeros((batch, D), jnp.float32)
        c["m"] = jnp.zeros((batch, D), jnp.float32)
    if spec.cross_attn:
        c["cross_k"] = jnp.zeros((batch, enc_len, hkv, hd), dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, hkv, hd), dtype)
    return c


def init_caches(cfg: ModelConfig, batch: int, seq_len: int,
                enc_len: int = 0) -> list:
    """Abstract cache pytree matching the grouped-scan layout."""
    out = []
    for repeat, body in cfg.layer_groups:
        slots = {f"slot{i}": init_block_cache(cfg, spec, batch, seq_len,
                                              enc_len)
                 for i, spec in enumerate(body)}
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (repeat,) + x.shape),
            slots))
    return out


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def block_decode(x, bp, spec: BlockSpec, cfg: ModelConfig, cache, pos):
    new_cache = dict(cache)
    h = L.apply_norm(x, bp["norm1"], cfg)
    if spec.mixer in (ATTN, SWA):
        win = cfg.window if spec.mixer == SWA else None
        y, kv = L.attention_decode(h, bp["mixer"], cfg,
                                   {"k": cache["k"], "v": cache["v"]},
                                   pos, window=win)
        new_cache["k"], new_cache["v"] = kv["k"], kv["v"]
    elif spec.mixer == RGLRU:
        y, st = L.rglru(h, bp["mixer"], cfg,
                        {"h": cache["h"], "conv": cache["conv"]})
        new_cache["h"], new_cache["conv"] = st["h"], st["conv"]
    elif spec.mixer == MLSTM:
        y, st = L.mlstm(h, bp["mixer"], cfg,
                        {k: cache[k] for k in ("C", "n", "m")})
        new_cache.update(st)
    elif spec.mixer == SLSTM:
        y, st = L.slstm(h, bp["mixer"], cfg,
                        {k: cache[k] for k in ("c", "n", "h", "m")})
        new_cache.update(st)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    if spec.cross_attn:
        h = L.apply_norm(x, bp["norm_cross"], cfg)
        y = L.attention(h, bp["cross"], cfg,
                        kv_override=(cache["cross_k"], cache["cross_v"]))
        x = x + y
    if spec.ffn == DENSE_FFN:
        x = x + L.ffn(L.apply_norm(x, bp["norm2"], cfg), bp["ffn"], cfg)
    elif spec.ffn == MOE_FFN:
        x = x + L.moe_ffn(L.apply_norm(x, bp["norm2"], cfg), bp["ffn"],
                          cfg)
    return x, new_cache


def decode_step(params, tokens, caches, pos, cfg: ModelConfig, *,
                ac: Callable = _ID_AC):
    """One decode step.  tokens: [B,1] int32; pos: scalar int32 (number of
    tokens already in the cache).  Returns (logits [B,1,V], new caches)."""
    x = ac(_embed(params, tokens, cfg), "residual")
    new_caches = []
    for (repeat, body), gp, gc in zip(cfg.layer_groups, params["groups"],
                                      caches):

        def body_fn(xc, inp, body=body):
            slot_params, cache_in = inp
            cache_out = {}
            for i, spec in enumerate(body):
                xc, c = block_decode(xc, slot_params[f"slot{i}"], spec,
                                     cfg, cache_in[f"slot{i}"], pos)
                cache_out[f"slot{i}"] = c
            return xc, cache_out

        x, new_gc = jax.lax.scan(body_fn, x, (gp, gc))
        new_caches.append(new_gc)
    x = L.apply_norm(x, params["final_norm"], cfg)
    return _logits(params, x, cfg, ac), new_caches


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def _mixer_prefill(x, bp, spec, cfg, positions, cache_len,
                   ac: Callable = _ID_AC):
    """Mixer over the full prompt, returning the filled cache."""
    h = L.apply_norm(x, bp["norm1"], cfg)
    B, S, _ = x.shape
    if spec.mixer in (ATTN, SWA):
        win = cfg.window if spec.mixer == SWA else None
        y = L.attention(h, bp["mixer"], cfg, causal=True, window=win,
                        positions=positions, ac=ac)
        k = (h @ bp["mixer"]["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        v = (h @ bp["mixer"]["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        k = L.rope(k, positions, cfg.rope_theta)
        if cache_len >= S:
            pad = cache_len - S
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:  # windowed ring cache keeps the tail; slot(p) = p % cache_len
            start = S - cache_len
            k, v = k[:, start:], v[:, start:]
            k = jnp.roll(k, shift=S % cache_len, axis=1)
            v = jnp.roll(v, shift=S % cache_len, axis=1)
        return y, {"k": k, "v": v}
    if spec.mixer == RGLRU:
        y, st = L.rglru(h, bp["mixer"], cfg, None)
        return y, st
    if spec.mixer == MLSTM:
        return L.mlstm(h, bp["mixer"], cfg, None)
    if spec.mixer == SLSTM:
        return L.slstm(h, bp["mixer"], cfg, None)
    raise ValueError(spec.mixer)


def prefill(params, batch, cfg: ModelConfig, *, cache_len: Optional[int]
            = None, ac: Callable = _ID_AC, enc_out=None):
    """Process a full prompt; returns (last-position logits, caches)."""
    x, positions, _ = _assemble_input(params, batch, cfg)
    x = ac(x, "residual")
    if cfg.encoder_decoder and enc_out is None and "enc_embeds" in batch:
        enc_x = (batch["enc_embeds"].astype(x.dtype)
                 @ params["frontend"]["proj"].astype(x.dtype))
        enc_pos = jnp.arange(enc_x.shape[1])[None, :]
        enc_groups = ((cfg.enc_layers,
                       (BlockSpec(mixer=ATTN, ffn=DENSE_FFN),)),)
        enc_out = _apply_groups(enc_x, params["enc"]["groups"], enc_groups,
                                cfg, enc_pos, causal=False, remat=False)
        enc_out = L.apply_norm(enc_out, params["enc"]["final_norm"], cfg)

    S = x.shape[1]
    cache_len = cache_len or S
    caches = []
    for (repeat, body), gp in zip(cfg.layer_groups, params["groups"]):

        def body_fn(xc, slot_params, body=body):
            cache_out = {}
            for i, spec in enumerate(body):
                clen = min(cache_len, _cache_len(cfg, spec, cache_len))
                y, c = _mixer_prefill(xc, slot_params[f"slot{i}"], spec,
                                      cfg, positions, clen, ac=ac)
                xc = ac(xc + y, "residual")
                bp = slot_params[f"slot{i}"]
                if spec.cross_attn and enc_out is not None:
                    h = L.apply_norm(xc, bp["norm_cross"], cfg)
                    kv = _cross_kv(enc_out, bp["cross"], cfg)
                    xc = ac(xc + L.attention(h, bp["cross"], cfg,
                                             kv_override=kv, ac=ac),
                            "residual")
                    c["cross_k"], c["cross_v"] = kv
                if spec.ffn == DENSE_FFN:
                    xc = ac(xc + L.ffn(L.apply_norm(xc, bp["norm2"], cfg),
                                       bp["ffn"], cfg, ac=ac), "residual")
                elif spec.ffn == MOE_FFN:
                    xc = ac(xc + L.moe_ffn(
                        L.apply_norm(xc, bp["norm2"], cfg), bp["ffn"],
                        cfg, ac=ac), "residual")
                cache_out[f"slot{i}"] = c
            return xc, cache_out

        x, gc = jax.lax.scan(body_fn, x, gp)
        caches.append(gc)
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = _logits(params, x[:, -1:, :], cfg, ac)
    return logits, caches
