"""Registry utilities: exact param counts and abstract input specs per
(architecture × shape) cell — the single source of truth for the dry-run,
smoke tests, and roofline accounting."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCfg
from . import model as M

WHISPER_CROSS_LEN = 1500  # encoder receptive field (30 s of audio)


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating anything."""
    return jax.eval_shape(partial(M.init_params, cfg=cfg),
                          jax.random.key(0))


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count, from the abstract init (not the analytic
    formula — this is what roofline MODEL_FLOPS uses)."""
    tree = abstract_params(cfg)
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token: MoE counts top_k of n_experts experts."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_mats = 3 if cfg.act == "swiglu" else 2
    per_expert = n_mats * cfg.d_model * m.d_ff_expert
    n_moe_layers = sum(1 for s in cfg.blocks() if s.ffn == "moe")
    return total - (m.n_experts - m.top_k) * per_expert * n_moe_layers


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _emb(b, s, d, dtype):
    return jax.ShapeDtypeStruct((b, s, d), jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    """Abstract batch for train/prefill-style full-sequence forward."""
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.compute_dtype
    if cfg.encoder_decoder:
        # audio stub: precomputed frame embeddings; decoder gets text tokens
        return {"enc_embeds": _emb(B, S, cfg.d_model, dt),
                "tokens": _tok(B, S)}
    if cfg.frontend == "patch":
        F = cfg.frontend_tokens
        return {"embeds": _emb(B, F, cfg.d_model, dt),
                "tokens": _tok(B, S - F)}
    return {"tokens": _tok(B, S)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeCfg
                       ) -> Tuple[Any, Any, Any]:
    """(tokens, caches, pos) ShapeDtypeStructs for one decode step with a
    cache of shape.seq_len tokens."""
    B, S = shape.global_batch, shape.seq_len
    enc_len = WHISPER_CROSS_LEN if cfg.encoder_decoder else 0
    caches = jax.eval_shape(
        lambda: M.init_caches(cfg, B, S, enc_len=enc_len))
    return _tok(B, 1), caches, jax.ShapeDtypeStruct((), jnp.int32)


def make_concrete(spec_tree, seed: int = 0):
    """Instantiate a spec tree with deterministic synthetic data (smoke
    tests / benchmarks)."""
    rng = np.random.default_rng(seed)

    def one(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, 255, size=s.shape),
                               dtype=s.dtype)
        return jnp.asarray(rng.normal(size=s.shape) * 0.02, dtype=s.dtype)

    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x,
                                                     jax.ShapeDtypeStruct))
