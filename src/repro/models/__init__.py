from .model import (decode_step, forward_train, init_caches, init_params,
                    prefill)

__all__ = ["init_params", "forward_train", "prefill", "decode_step",
           "init_caches"]
