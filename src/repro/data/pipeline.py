"""Deterministic, seekable synthetic data pipeline.

Batches are a pure function of (seed, step) — counter-based hashing, no
iterator state — so a restarted or live-migrated job resumes mid-stream
exactly (the data-side requirement for fault tolerance; the same property
the paper needs from its RNG-bearing kernels).  Batches are placed with the
mesh sharding so the input pipeline is distribution-aware.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeCfg


class SyntheticLMData:
    def __init__(self, cfg: ModelConfig, shape: ShapeCfg, seed: int = 0,
                 mesh=None, specs=None, batch_override: Optional[int]
                 = None, seq_override: Optional[int] = None):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.mesh = mesh
        self.specs = specs
        self.B = batch_override or shape.global_batch
        self.S = seq_override or shape.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step (Philox counter RNG)."""
        rng = np.random.Generator(np.random.Philox(key=self.seed,
                                                   counter=[0, 0, 0, step]))
        cfg, B, S = self.cfg, self.B, self.S
        if cfg.encoder_decoder:
            batch = {"enc_embeds": rng.normal(size=(B, S, cfg.d_model))
                     .astype(np.float32) * 0.02,
                     "tokens": rng.integers(0, cfg.vocab_size, (B, S))
                     .astype(np.int32)}
        elif cfg.frontend == "patch":
            F = cfg.frontend_tokens
            batch = {"embeds": rng.normal(size=(B, F, cfg.d_model))
                     .astype(np.float32) * 0.02,
                     "tokens": rng.integers(0, cfg.vocab_size, (B, S - F))
                     .astype(np.int32)}
        else:
            batch = {"tokens": rng.integers(0, cfg.vocab_size, (B, S))
                     .astype(np.int32)}
        if self.mesh is not None and self.specs is not None:
            batch = {
                k: jax.device_put(
                    v, NamedSharding(self.mesh, self.specs[k]))
                for k, v in batch.items()
            }
        return batch
