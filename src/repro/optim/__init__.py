from .adamw import adamw_init, adamw_update
from .schedule import warmup_cosine

__all__ = ["adamw_init", "adamw_update", "warmup_cosine"]
