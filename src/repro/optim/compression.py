"""Gradient compression: int8 quantization with error feedback.

Drops the gradient reduce-scatter volume 4× (f32) / 2× (bf16).  Per-leaf
symmetric int8 quantization with a per-leaf scale; the quantization error
is carried in an error-feedback buffer and added back before the next
quantization (Seide et al. / EF-SGD), which keeps SGD/Adam convergence
unbiased in expectation.

Opt-in via ``ParallelCfg.grad_compression="int8_ef"``; the buffers shard
exactly like the gradients (they mirror the parameter specs).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def ef_init(params) -> Any:
    """Zero error-feedback buffers mirroring the parameter pytree."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, ef_buf) -> Tuple[Any, Any]:
    """Quantize (grads + carried error) to int8; returns (quantized tree
    of (q, scale), new error buffers)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        err = gf - q.astype(jnp.float32) * scale
        return (q, scale), err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_buf)
    qs, errs = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return jax.tree.unflatten(tdef, list(qs)), \
        jax.tree.unflatten(tdef, list(errs))


def decompress(qtree) -> Any:
    return jax.tree.map(
        lambda leaf: leaf[0].astype(jnp.float32) * leaf[1],
        qtree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and hasattr(x[0], "dtype"))


def compressed_bytes(qtree) -> int:
    return sum(leaf[0].size + 4 for leaf in jax.tree.leaves(
        qtree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and hasattr(x[0], "dtype")))
