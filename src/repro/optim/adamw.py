"""AdamW with dtype-configurable moment states and global-norm clipping.

Moment states mirror the parameter pytree (and inherit its sharding), so
ZeRO-3/FSDP sharding of optimizer state falls out of the param specs.
405B-class configs run bf16 moments (see DESIGN.md §5 memory napkin);
everything else defaults to f32.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params, state_dtype: str = "float32") -> Dict[str, Any]:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    count = opt_state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay \
            * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
