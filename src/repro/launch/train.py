"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --shape train_4k --steps 1000 --ckpt /path/ck [--multi-pod]

On this CPU container only smoke-scale runs execute; on a real TPU slice
the same entry point drives the production mesh (the mesh shape is the
only difference — the model/runtime code is mesh-agnostic).
"""
import argparse

import jax

from repro import configs
from repro.configs.base import SHAPES, ShapeCfg, default_parallel
from repro.launch.mesh import make_production_mesh
from repro.runtime.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny mesh (CPU debugging)")
    args = ap.parse_args()

    if args.smoke:
        cfg = configs.get_smoke_config(args.arch)
        shape = ShapeCfg("smoke", 64, 4, "train")
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))
    else:
        cfg = configs.get_config(args.arch)
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    pcfg = default_parallel(cfg, shape)
    trainer = Trainer(cfg, shape, mesh, pcfg=pcfg, ckpt_dir=args.ckpt)
    trainer.maybe_restore()
    rep = trainer.run(args.steps,
                      checkpoint_every=args.checkpoint_every)
    print(f"ran {rep.steps_run} steps; final loss "
          f"{rep.losses[-1] if rep.losses else float('nan'):.4f}; "
          f"checkpoints at {rep.checkpoints}")


if __name__ == "__main__":
    main()
