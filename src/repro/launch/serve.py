"""Production serving launcher: continuous batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import SHAPES, default_parallel
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
    if cfg.frontend == "patch":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
        batch["tokens"] = batch["tokens"][:, cfg.frontend_tokens:]

    logits, caches = jax.jit(lambda p, b: prefill(
        p, b, cfg, cache_len=S + args.steps))(params, batch)
    step = jax.jit(lambda p, t, c, q: decode_step(p, t, c, q, cfg))
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.steps - 1):
        logits, caches = step(params, toks, caches,
                              jnp.asarray(S + i, jnp.int32))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
            .astype(jnp.int32)
    jax.block_until_ready(toks)
    n = (args.steps - 1) * B
    print(f"{n} tokens in {time.perf_counter()-t0:.2f}s")


if __name__ == "__main__":
    main()
