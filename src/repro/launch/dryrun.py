import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first lines: jax locks the device count on first init.
# Everything below may import jax.
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import SHAPES, default_parallel, shape_applicable  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.models import registry as R  # noqa: E402
from repro.optim import adamw_init  # noqa: E402
from repro.parallel import MeshRules, make_serve_step, make_train_step  # noqa: E402
from repro.parallel.steps import make_prefill_step  # noqa: E402

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" \
    / "dryrun"


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _abstract_opt(cfg, params):
    return jax.eval_shape(lambda p: adamw_init(p, cfg.opt_state_dtype),
                          params)


def _analytic_bytes_per_device(tree, specs, axis_size) -> float:
    """Sum of leaf bytes divided by their sharded axis product."""
    total = 0.0
    for leaf, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(specs, is_leaf=lambda x:
                                          isinstance(x, P))):
        n = 1
        for entry in spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                n *= axis_size[a]
        total += leaf.size * leaf.dtype.itemsize / n
    return total


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               pcfg_override=None, cfg_overrides=None):
    """Lower + compile one (arch × shape × mesh) cell; returns metrics."""
    import dataclasses
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    pcfg = pcfg_override or default_parallel(cfg, shape)
    rules = MeshRules(cfg, pcfg, mesh)
    n_chips = int(np.prod(mesh.devices.shape))

    params = R.abstract_params(cfg)
    pspecs = rules.param_specs()

    t0 = time.time()
    if shape.kind == "train":
        opt = _abstract_opt(cfg, params)
        ospecs = rules.opt_specs(pspecs)
        batch = R.train_input_specs(cfg, shape)
        bspecs = rules.batch_specs(batch)
        step_fn = make_train_step(cfg, pcfg, rules)
        jitted = jax.jit(step_fn,
                         in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs),
                                       _ns(mesh, bspecs),
                                       NamedSharding(mesh, P())))
        with mesh:
            lowered = jitted.lower(
                params, opt, batch, jax.ShapeDtypeStruct((), jnp.int32))
        state_bytes = (_analytic_bytes_per_device(params, pspecs,
                                                  rules.axis_size)
                       + _analytic_bytes_per_device(
                           opt["m"], pspecs, rules.axis_size) * 2)
    elif shape.kind == "prefill":
        batch = R.train_input_specs(cfg, shape)
        bspecs = rules.batch_specs(batch)
        step_fn = make_prefill_step(cfg, rules)
        jitted = jax.jit(step_fn, in_shardings=(_ns(mesh, pspecs),
                                                _ns(mesh, bspecs)))
        with mesh:
            lowered = jitted.lower(params, batch)
        state_bytes = _analytic_bytes_per_device(params, pspecs,
                                                 rules.axis_size)
    else:  # decode
        tokens, caches, pos = R.decode_input_specs(cfg, shape)
        cspecs = rules.cache_specs(caches)
        tspecs = rules.batch_specs({"tokens": tokens})["tokens"]
        step_fn = make_serve_step(cfg, rules)
        jitted = jax.jit(step_fn,
                         in_shardings=(_ns(mesh, pspecs),
                                       NamedSharding(mesh, tspecs),
                                       _ns(mesh, cspecs),
                                       NamedSharding(mesh, P())))
        with mesh:
            lowered = jitted.lower(params, tokens, caches, pos)
        state_bytes = (_analytic_bytes_per_device(params, pspecs,
                                                  rules.axis_size)
                       + _analytic_bytes_per_device(caches, cspecs,
                                                    rules.axis_size))
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # ---- analyses ------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        mem_info = {k: int(getattr(mem, k)) for k in dir(mem)
                    if k.endswith("_size_in_bytes")
                    and isinstance(getattr(mem, k), (int, np.integer))}
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else cost
        flops_flat = float(cost.get("flops", -1.0))
        bytes_flat = float(cost.get("bytes accessed", -1.0))
    except Exception as e:  # pragma: no cover
        flops_flat, bytes_flat = -1.0, -1.0

    # loop-weighted per-device accounting from the optimized HLO
    # (XLA cost_analysis counts while bodies once — see hlo_analysis)
    hlo = compiled.as_text()
    analysis = hlo_analysis.analyze_hlo(hlo, default_group=n_chips)
    flops = float(analysis["flops"])
    bytes_accessed = float(analysis["bytes"])
    coll = dict(analysis["collectives"])

    # ---- roofline terms (per spec formulas) ------------------------------
    N = R.active_param_count(cfg)
    if shape.kind == "train":
        D_tokens = shape.seq_len * shape.global_batch
        model_flops = 6.0 * N * D_tokens
    elif shape.kind == "prefill":
        D_tokens = shape.seq_len * shape.global_batch
        model_flops = 2.0 * N * D_tokens
    else:
        D_tokens = shape.global_batch  # one token per sequence
        model_flops = 2.0 * N * D_tokens

    # flops / bytes / collective bytes from the analyzer are PER-DEVICE
    compute_s = flops / PEAK_FLOPS_BF16 if flops > 0 else None
    memory_s = bytes_accessed / HBM_BW if bytes_accessed > 0 else None
    collective_s = coll.get("total", 0.0) / ICI_BW  # per-device bytes / link

    result = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "hlo_flops": flops,                # per-device, loop-weighted
        "hlo_bytes": bytes_accessed,       # per-device, loop-weighted
        "hlo_flops_flat": flops_flat,      # raw cost_analysis (body-once)
        "hlo_bytes_flat": bytes_flat,
        "collective_bytes": coll,          # per-device, loop-weighted
        "model_flops": model_flops,        # whole-job analytic 6·N·D
        "params_total": R.param_count(cfg),
        "params_active": N,
        "state_bytes_per_device": state_bytes,
        "memory_analysis": mem_info,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
        },
        "parallel": {
            "grad_accum": pcfg.grad_accum, "seq_shard": pcfg.seq_shard,
            "kv_shard": pcfg.kv_shard, "remat": pcfg.remat,
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="single arch (default: all)")
    ap.add_argument("--shape", default=None,
                    help="single shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ART_DIR))
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (hillclimb variants)")
    ap.add_argument("--pset", action="append", default=[],
                    help="ParallelCfg override key=value")
    args = ap.parse_args()

    def _parse(v: str):
        for cast in (int, float):
            try:
                return cast(v)
            except ValueError:
                pass
        return {"true": True, "false": False}.get(v.lower(), v)

    cfg_overrides = dict(kv.split("=", 1) for kv in args.set)
    cfg_overrides = {k: _parse(v) for k, v in cfg_overrides.items()}
    pcfg_overrides = dict(kv.split("=", 1) for kv in args.pset)
    pcfg_overrides = {k: _parse(v) for k, v in pcfg_overrides.items()}

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = [args.arch] if args.arch else configs.list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                cell = f"{arch}__{shape}__{mesh_name}"
                fpath = out_dir / f"{cell}__{args.tag}.json"
                if fpath.exists():
                    print(f"[skip-cached] {cell}")
                    continue
                print(f"[lower+compile] {cell} ...", flush=True)
                t0 = time.time()
                try:
                    pov = None
                    if pcfg_overrides:
                        import dataclasses as _dc
                        base_p = default_parallel(
                            configs.get_config(arch), SHAPES[shape])
                        pov = _dc.replace(base_p, **pcfg_overrides)
                    res = lower_cell(arch, shape, mp,
                                     pcfg_override=pov,
                                     cfg_overrides=cfg_overrides or None)
                except Exception as e:
                    res = {"status": "error", "error": str(e),
                           "trace": traceback.format_exc()}
                    failures += 1
                    print(f"  ERROR: {e}")
                res["tag"] = args.tag
                fpath.write_text(json.dumps(res, indent=1))
                print(f"  -> {res['status']} in {time.time()-t0:.1f}s",
                      flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
