"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run entry
point must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

# v5e-class hardware constants used across roofline accounting
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_BYTES = 16 * 2 ** 30      # per chip


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for host-device tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count >= prod(shape))."""
    return jax.make_mesh(shape, axes)
