"""Post-SPMD HLO analysis: loop-weighted FLOPs / bytes / collective traffic.

``compiled.cost_analysis()`` on XLA:CPU counts while-loop bodies ONCE and
reports per-device numbers, which silently undercounts scan-over-layers /
grad-accum models by orders of magnitude.  This module computes per-device,
trip-count-weighted totals directly from the optimized HLO text:

* computations are parsed structurally (header line ending in ``{``,
  closing ``}`` line) and costed bottom-up through the call graph
  (`while` bodies × known_trip_count, fusions, calls, conditionals);
* FLOPs: dots = 2·prod(out)·K (K from contracting dims), elementwise =
  prod(out); fusion FLOPs come from the fused computation;
* bytes: operand+output sizes of top-level (non-fused) ops — fusion
  internals cost 0 bytes, the fusion call line carries the HBM traffic;
* collectives use ring-model per-device byte counts:
    all-reduce         2·bytes(out)·(n-1)/n
    all-gather         bytes(out)·(n-1)/n
    reduce-scatter     bytes(out)·(n-1)
    all-to-all         bytes(out)·(n-1)/n
    collective-permute bytes(out)
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|[^\s(]+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\)|[^\s(]+))\s+"
    r"([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\.)")

# ops that move no data / do no work
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id",
             "get-dimension-size", "opt-barrier", "domain", "token"}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shapes_in(type_str: str) -> List[Tuple[str, int]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n))
    return out


def _shape_bytes(type_str: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shapes_in(type_str))


def _shape_elems(type_str: str) -> int:
    return sum(n for _, n in _shapes_in(type_str))


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"source_target_pairs", line)
    if m:  # collective-permute
        return 2
    return default


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                     r"((?:\([^)]*\)|[^\s(]+))\s")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


class HloAnalyzer:
    def __init__(self, hlo: str, default_group: int):
        self.default_group = default_group
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo)
        # per-computation symbol table: result name -> type string
        # (optimized HLO prints operands WITHOUT types, so byte/FLOP
        # accounting must resolve them through the defs)
        self.symtab: Dict[str, Dict[str, str]] = {}
        for name, body in self.comps.items():
            tab: Dict[str, str] = {}
            for line in body:
                dm = _DEF_RE.match(line)
                if dm:
                    tab[dm.group(1)] = dm.group(2)
            self.symtab[name] = tab
        self._memo: Dict[Tuple[str, bool], Cost] = {}
        self._sliced_memo: Dict[str, Dict[int, int]] = {}

    # -- structural parse --------------------------------------------------
    def _parse(self, hlo: str) -> None:
        cur: Optional[str] = None
        body: List[str] = []
        for raw in hlo.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if cur is None:
                # computation header: column-0 `[ENTRY ]%name (args) -> type {`
                # (op lines are indented; `/*index=N*/` comments mean the
                # param list may contain `=`, so no `=` filtering)
                if not raw[:1].isspace() and stripped.endswith("{") \
                        and "->" in stripped:
                    m = _HEADER_RE.match(stripped)
                    if m:
                        cur = m.group(2)
                        body = []
                        if m.group(1):
                            self.entry = cur
            else:
                if stripped == "}" or stripped.startswith("} "):
                    self.comps[cur] = body
                    cur = None
                else:
                    body.append(stripped)

    # -- operand helpers ----------------------------------------------------
    def _operand_types(self, line: str, comp: str) -> List[str]:
        """Types of the operand list of an op line (via the symtab)."""
        _, _, tail = line.partition("(")
        # operand list ends at the first "), " attribute separator or at
        # the closing paren of the op
        cut = len(tail)
        for marker in ("), ", ") "):
            idx = tail.find(marker)
            if idx >= 0:
                cut = min(cut, idx)
        args = tail[:cut]
        tab = self.symtab.get(comp, {})
        return [tab[n] for n in _OPERAND_RE.findall(args) if n in tab]

    # -- per-line costing ---------------------------------------------------
    def _line_cost(self, line: str, in_fusion: bool, comp: str = "") -> Cost:
        c = Cost()
        m = _OP_RE.match(line)
        if not m:
            return c
        out_type, op = m.group(1), m.group(2)
        if op in _FREE_OPS:
            return c

        # nested computation references
        trips = 1
        mt = re.search(r"known_trip_count[^0-9]*(\d+)", line)
        if mt:
            trips = int(mt.group(1))

        if op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", line)
            mc = re.search(r"condition=%?([\w.\-]+)", line)
            if mb and mb.group(1) in self.comps:
                c.add(self._comp_cost(mb.group(1), in_fusion), trips)
            if mc and mc.group(1) in self.comps:
                c.add(self._comp_cost(mc.group(1), in_fusion), trips)
            return c
        if op == "fusion":
            mcalls = re.search(r"calls=%?([\w.\-]+)", line)
            called = mcalls.group(1) if mcalls else None
            if called in self.comps:
                inner = self._comp_cost(called, True)
                c.flops += inner.flops
                for k, v in inner.coll.items():
                    c.coll[k] += v
            if not in_fusion:
                c.bytes += self._fusion_bytes(line, out_type, comp, called)
            return c
        if op in ("call", "async-start"):
            mc = re.search(r"to_apply=%?([\w.\-]+)", line)
            if mc and mc.group(1) in self.comps:
                c.add(self._comp_cost(mc.group(1), in_fusion))
            return c
        if op == "conditional":
            branches = re.findall(
                r"(?:true_computation|false_computation|"
                r"branch_computations=\{[^}]*)=?%?([\w.\-]+)", line)
            best = Cost()
            for bname in branches:
                if bname in self.comps:
                    bc = self._comp_cost(bname, in_fusion)
                    if bc.flops >= best.flops:
                        best = bc
            c.add(best)
            return c

        # collectives
        cm = _COLL_RE.search(line)
        if cm and op.replace("-start", "") in _COLL_KINDS:
            kind = cm.group(2)
            nbytes = _shape_bytes(cm.group(1))
            n = _group_size(line, self.default_group)
            if kind == "all-reduce":
                moved = 2 * nbytes * (n - 1) / max(n, 1)
            elif kind == "all-gather":
                moved = nbytes * (n - 1) / max(n, 1)
            elif kind == "reduce-scatter":
                moved = nbytes * (n - 1)
            elif kind == "all-to-all":
                moved = nbytes * (n - 1) / max(n, 1)
            else:
                moved = nbytes
            c.coll[kind] += moved
            c.coll["total"] += moved
            c.coll[f"count_{kind}"] += 1
            if not in_fusion:
                c.bytes += self._line_bytes(line, out_type, comp)
            return c

        # slicing ops move only the slice, not the (possibly huge) operand
        # buffer — every scan iteration dynamic-slices its stacked xs, so
        # charging full operands would overcount by the trip count.
        if op == "dynamic-slice" or op == "slice":
            c.bytes += 2 * _shape_bytes(out_type) if not in_fusion else 0
            c.flops += 0
            return c
        if op == "dynamic-update-slice":
            ops_ = self._operand_types(line, comp)
            upd = _shape_bytes(ops_[1]) if len(ops_) > 1 \
                else _shape_bytes(out_type)
            if not in_fusion:
                c.bytes += 3 * upd  # read update + read/write touched rows
            return c
        if op == "gather":
            if not in_fusion:
                c.bytes += 2 * _shape_bytes(out_type)
            return c
        if op == "scatter":
            ops_ = self._operand_types(line, comp)
            upd = _shape_bytes(ops_[-1]) if ops_ else _shape_bytes(out_type)
            if not in_fusion:
                c.bytes += 3 * upd
            c.flops += _shape_elems(out_type) * 0  # negligible
            return c

        # plain compute op
        if op == "dot":
            c.flops += self._dot_flops(line, out_type, comp)
        elif op == "convolution":
            c.flops += 2 * _shape_elems(out_type)
        elif op in ("reduce", "reduce-window", "scatter", "select-and-scatter",
                    "sort", "map"):
            ops_ = self._operand_types(line, comp)
            c.flops += sum(_shape_elems(t) for t in ops_)
        else:
            c.flops += _shape_elems(out_type)
        if not in_fusion:
            c.bytes += self._line_bytes(line, out_type, comp)
        return c

    def _dot_flops(self, line: str, out_type: str, comp: str) -> float:
        out_elems = _shape_elems(out_type)
        ops_ = self._operand_types(line, comp)
        mlc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        if not ops_ or mlc is None:
            return 2.0 * out_elems
        lhs = _SHAPE_RE.search(ops_[0])
        lhs_dims = [int(d) for d in lhs.group(2).split(",")] \
            if lhs and lhs.group(2) else []
        k = 1
        for i in (int(x) for x in mlc.group(1).split(",") if x):
            if i < len(lhs_dims):
                k *= lhs_dims[i]
        return 2.0 * out_elems * k

    def _line_bytes(self, line: str, out_type: str, comp: str) -> float:
        return _shape_bytes(out_type) + sum(
            _shape_bytes(t) for t in self._operand_types(line, comp))

    def _fusion_bytes(self, line: str, out_type: str, comp: str,
                      called: Optional[str]) -> float:
        """HBM traffic of a fusion call: output + operands — but operands
        that are only *sliced/gathered* inside the fused computation move
        only the slice (scan xs are dynamic-sliced per iteration; charging
        the full stacked buffer would overcount by the trip count)."""
        total = _shape_bytes(out_type)
        op_types = self._operand_types(line, comp)
        sliced = self._sliced_params(called) if called else {}
        for i, t in enumerate(op_types):
            if i in sliced:
                total += sliced[i]
            else:
                total += _shape_bytes(t)
        return total

    def _sliced_params(self, called: str) -> Dict[int, int]:
        """Map fusion-parameter index -> bytes actually touched, for
        parameters whose only consumers are dynamic-slice / gather reads
        or dynamic-update-slice writes INTO the parameter (scan xs reads
        and scan carry/grad-stack writes — charging the full stacked
        buffer would overcount by the trip count)."""
        if called in self._sliced_memo:
            return self._sliced_memo[called]
        body = self.comps.get(called, ())
        tab = self.symtab.get(called, {})
        params: Dict[str, int] = {}
        for ln in body:
            m = re.match(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\S+\s+"
                         r"parameter\((\d+)\)", ln)
            if m:
                params[m.group(1)] = int(m.group(2))
        out: Dict[int, int] = {}
        for pname, pidx in params.items():
            touched = 0
            ok = True
            for ln in body:
                if f"%{pname}" not in ln:
                    continue
                dm = _DEF_RE.match(ln)
                if dm and dm.group(1) == pname:
                    continue  # the def line itself
                om = _OP_RE.match(ln)
                opk = om.group(2) if om else ""
                args = _OPERAND_RE.findall(ln.partition("(")[2])
                if opk in ("dynamic-slice", "gather", "slice") \
                        and args and args[0] == pname:
                    touched += 2 * _shape_bytes(om.group(1))
                elif opk == "dynamic-update-slice" and args \
                        and args[0] == pname:
                    # write of the update slice into the buffer
                    upd_t = tab.get(args[1], "") if len(args) > 1 else ""
                    touched += 3 * _shape_bytes(upd_t)
                else:
                    ok = False
                    break
            if ok and touched:
                out[pidx] = touched
        self._sliced_memo[called] = out
        return out

    # -- computation costing -------------------------------------------------
    def _comp_cost(self, name: str, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # break cycles defensively
        total = Cost()
        for line in self.comps.get(name, ()):
            total.add(self._line_cost(line, in_fusion, name))
        self._memo[key] = total
        return total

    def analyze(self) -> Dict[str, object]:
        entry = self.entry or next(iter(self.comps), None)
        if entry is None:
            return {"flops": 0.0, "bytes": 0.0, "collectives": {}}
        c = self._comp_cost(entry, False)
        return {"flops": c.flops, "bytes": c.bytes,
                "collectives": dict(c.coll)}


def analyze_hlo(hlo: str, default_group: int) -> Dict[str, object]:
    return HloAnalyzer(hlo, default_group).analyze()


# backwards-compatible helpers ------------------------------------------------

def collective_bytes(hlo: str, default_group: int) -> Dict[str, float]:
    res = analyze_hlo(hlo, default_group)
    return dict(res["collectives"])


_CALL_RE = _COLL_RE  # used by debug tooling
